"""Serving robustness (DESIGN.md C13): per-request inference/extraction
failures map to ``Response.status == "error"`` instead of crashing the
stage loop, and `ReplicatedServer` evicts a failed engine from the
balancer and requeues its in-flight requests onto the survivors."""
import numpy as np
import pytest

from repro.distributed.chaos import ChaosInjector, FaultPlan
from repro.serving.batcher import GNNBatcher, Request
from repro.serving.engine import GNNServingEngine, ServingConfig
from repro.serving.pipeline import EngineFailure, ServingPipeline
from repro.serving.replicate import ReplicatedServer


def _fixture(batch_size=16, **cfg_kw):
    import jax
    from repro.core.models import make_gnn_stack, init_stack
    from repro.graphs.generate import rmat_graph, random_features

    g = rmat_graph(300, 2400, seed=0).gcn_normalized()
    x = random_features(300, 8, seed=1)
    layers = make_gnn_stack("gcn", [8, 16, 4])
    params = init_stack(layers, jax.random.key(0))
    cfg = ServingConfig(batch_size=batch_size, cache_capacity=0, **cfg_kw)
    return g, x, layers, params, cfg


def _requests(n=24, n_vertices=300, seed=3):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, n_vertices,
                             rng.integers(1, 9)).astype(np.int32))
            for i in range(n)]


# ----------------------------------------------------- batcher fail path
def test_batcher_fail_answers_with_error_status():
    b = GNNBatcher(None, batch_size=8)
    b.submit(Request(1, np.arange(3, dtype=np.int32)))
    b.submit(Request(2, np.arange(4, dtype=np.int32)))
    batch = b.admit()
    errs = b.fail(batch)
    assert sorted(r.rid for r in errs) == [1, 2]
    assert all(r.status == "error" and r.outputs.size == 0 for r in errs)
    assert b.stats["errors"] == 2
    assert not b.queue                  # nothing left to serve
    # failing the same batch twice emits nothing new
    assert b.fail(batch) == []


def test_batcher_fail_removes_split_request_remainder():
    """A partially-admitted head request is answered once and its
    unadmitted tail leaves the queue; a later batch completing one of
    its earlier slices stays silent."""
    b = GNNBatcher(None, batch_size=4)
    b.submit(Request(7, np.arange(10, dtype=np.int32)))
    first = b.admit()                   # slices [0:4], request stays queued
    second = b.admit()                  # slices [4:8]
    errs = b.fail(second)
    assert [r.rid for r in errs] == [7]
    assert not b.queue                  # the tail [8:10] was evicted
    # the in-flight first batch completes later: dropped silently
    out = np.zeros((first.ids.size, 2), np.float32)
    assert b.complete(first, out) == []


# ----------------------------------------------- pipeline error mapping
def test_pipeline_maps_inference_failure_to_error_response(monkeypatch):
    pl = ServingPipeline(GNNServingEngine(*_fixture()[:4],
                                          _fixture()[4]))
    inj = ChaosInjector(FaultPlan())
    # fail the 2nd inference only; the loop must keep serving
    monkeypatch.setattr(pl.engine, "_infer_batch",
                        inj.wrap_callable(pl.engine._infer_batch,
                                          calls=(1,)))
    for rid, ids in _requests(12):
        pl.submit(rid, ids)
    responses = pl.drain()
    pl.close()
    by_status = {}
    for r in responses:
        by_status.setdefault(r.status, []).append(r.rid)
    assert by_status.get("error"), "no error responses mapped"
    assert by_status.get("ok"), "the stage loop stopped serving"
    assert len(responses) == 12         # every request answered
    assert pl.stats["batch_errors"] >= 1


def test_pipeline_maps_extraction_failure_to_error_response(monkeypatch):
    g, x, layers, params, cfg = _fixture(extract_workers=0)
    pl = ServingPipeline(GNNServingEngine(g, x, layers, params, cfg))
    inj = ChaosInjector(FaultPlan())
    monkeypatch.setattr(pl.engine, "_extract_batch",
                        inj.wrap_callable(pl.engine._extract_batch,
                                          calls=(0,)))
    for rid, ids in _requests(8):
        pl.submit(rid, ids)
    responses = pl.drain()
    pl.close()
    statuses = {r.status for r in responses}
    assert "error" in statuses and "ok" in statuses
    assert len(responses) == 8


def test_pipeline_pool_extraction_failure_maps_too(monkeypatch):
    """With worker threads the extraction exception surfaces from the
    future at completion time — same error mapping."""
    g, x, layers, params, cfg = _fixture(extract_workers=2)
    pl = ServingPipeline(GNNServingEngine(g, x, layers, params, cfg))
    inj = ChaosInjector(FaultPlan())
    monkeypatch.setattr(pl.engine, "_extract_batch",
                        inj.wrap_callable(pl.engine._extract_batch,
                                          calls=(0,)))
    for rid, ids in _requests(8):
        pl.submit(rid, ids)
    responses = pl.drain()
    pl.close()
    statuses = {r.status for r in responses}
    assert "error" in statuses and "ok" in statuses
    assert len(responses) == 8


def test_engine_failure_escalates_out_of_pipeline(monkeypatch):
    pl = ServingPipeline(GNNServingEngine(*_fixture()[:4],
                                          _fixture()[4]))

    def dead(*a, **k):
        raise EngineFailure("device lost")

    monkeypatch.setattr(pl.engine, "_infer_batch", dead)
    for rid, ids in _requests(4):
        pl.submit(rid, ids)
    with pytest.raises(EngineFailure):
        pl.drain()
    # the failed ticket was pushed back for an evicting caller
    assert pl.inflight
    pl.close()


# -------------------------------------------------- replicated eviction
def _replicated(replicas=2, **cfg_kw):
    g, x, layers, params, cfg = _fixture(**cfg_kw)
    return ReplicatedServer(g, x, layers, params, replicas=replicas,
                            config=cfg, balancer="round_robin")


def test_replicated_server_evicts_and_requeues(monkeypatch):
    srv = _replicated(replicas=2)

    def dead(*a, **k):
        raise EngineFailure("replica 0 died")

    monkeypatch.setattr(srv.engines[0], "_infer_batch", dead)
    reqs = _requests(10)
    for rid, ids in reqs:
        srv.submit(rid, ids)
    assert int(srv.routed[0]) > 0       # replica 0 got traffic
    responses = srv.drain()
    srv.close()
    # every request answered ok by the survivor — at-least-once
    ok = {r.rid for r in responses if r.status == "ok"}
    assert ok == {rid for rid, _ in reqs}
    tele = srv.telemetry()
    assert tele["alive"] == [False, True]
    assert tele["evictions"] == 1
    assert tele["requeued"] > 0


def test_evicted_replica_receives_no_traffic(monkeypatch):
    srv = _replicated(replicas=3)
    monkeypatch.setattr(
        srv.engines[1], "_infer_batch",
        lambda *a, **k: (_ for _ in ()).throw(EngineFailure("dead")))
    for rid, ids in _requests(9):
        srv.submit(rid, ids)
    srv.drain()
    routed_before = srv.routed.copy()
    for rid, ids in _requests(9, seed=5):
        srv.submit(1000 + rid, ids)
    assert srv.routed[1] == routed_before[1]    # nothing new routed to 1
    responses = srv.drain()
    srv.close()
    assert all(r.status == "ok" for r in responses)


def test_all_replicas_evicted_raises(monkeypatch):
    srv = _replicated(replicas=2)
    for e in srv.engines:
        monkeypatch.setattr(
            e, "_infer_batch",
            lambda *a, **k: (_ for _ in ()).throw(EngineFailure("dead")))
    for rid, ids in _requests(4):
        srv.submit(rid, ids)
    with pytest.raises(RuntimeError, match="no replicas survive"):
        srv.drain()
    srv.close()
    with pytest.raises(RuntimeError, match="no alive replicas"):
        srv.submit(99, np.arange(3, dtype=np.int32))
