"""The async SLO-driven serving pipeline (DESIGN.md C12): deadline
admission control, bounded in-flight backpressure, pipeline-vs-sync
equivalence, replicated engines, the workload generator, cache
warm-fill, the ServingConfig/EnGNConfig unification shim, and the typed
`PreparedPlan` returned by every prepare_* entry point."""
import time

import numpy as np
import pytest

from repro.serving.batcher import GNNBatcher, Request
from repro.serving.engine import GNNServingEngine, ServingConfig
from repro.serving.pipeline import ServingPipeline
from repro.serving.replicate import ReplicatedServer
from repro.serving.workload import (WorkloadSpec, make_trace, replay_closed)


def _echo_infer(ids):
    return np.stack([ids, ids * 2], axis=1).astype(np.float32)


def _fixture(batch_size=16, cache_capacity=0, **cfg_kw):
    import jax
    from repro.core.models import make_gnn_stack, init_stack
    from repro.graphs.generate import rmat_graph, random_features

    g = rmat_graph(300, 2400, seed=0).gcn_normalized()
    x = random_features(300, 8, seed=1)
    layers = make_gnn_stack("gcn", [8, 16, 4])
    params = init_stack(layers, jax.random.key(0))
    cfg = ServingConfig(batch_size=batch_size,
                        cache_capacity=cache_capacity, **cfg_kw)
    return g, x, layers, params, cfg


def _requests(n=24, n_vertices=300, seed=3):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, n_vertices,
                             rng.integers(1, 9)).astype(np.int32))
            for i in range(n)]


# ------------------------------------------------------ deadline shedding
def test_batcher_sheds_expired_requests():
    """A queued request whose deadline has passed is answered
    status="expired" with empty outputs; live ones survive."""
    b = GNNBatcher(_echo_infer, batch_size=8)
    now = time.monotonic()
    b.submit(Request(1, np.arange(3, dtype=np.int32),
                     deadline_s=now - 0.1))
    b.submit(Request(2, np.arange(3, dtype=np.int32),
                     deadline_s=now + 60.0))
    b.submit(Request(3, np.arange(3, dtype=np.int32)))   # no SLO
    shed = b.shed_expired(now)
    assert [r.rid for r in shed] == [1]
    assert shed[0].status == "expired" and shed[0].outputs.size == 0
    assert b.stats["shed"] == 1
    served = b.drain()
    assert sorted(r.rid for r in served) == [2, 3]
    assert all(r.status == "ok" for r in served)


def test_batcher_shed_uses_eta_and_spares_inflight():
    """With an ETA model, a deadline that the queue estimate says will
    be missed sheds proactively; partially-admitted requests are never
    shed (their slices are already in flight)."""
    b = GNNBatcher(_echo_infer, batch_size=4)
    now = time.monotonic()
    b.submit(Request(1, np.arange(10, dtype=np.int32),
                     deadline_s=now + 1.0))              # head: split
    b.step()                                             # admit one slice
    b.submit(Request(2, np.arange(4, dtype=np.int32),
                     deadline_s=now + 1.0))
    # brutal ETA: every queued vertex costs 1s => rid 2 cannot make it,
    # rid 1 is in flight and must survive regardless
    shed = b.shed_expired(now, eta_s=lambda ahead: float(ahead))
    assert [r.rid for r in shed] == [2]
    served = b.drain()
    assert [r.rid for r in served] == [1]


def test_pipeline_sheds_late_request_with_expired_status():
    pl = ServingPipeline(GNNServingEngine(*_fixture()[:4], _fixture()[4]))
    pl.submit(0, np.arange(4, dtype=np.int32))
    pl.drain()                                           # trains the EWMA
    assert pl._ewma_s_per_vertex is not None
    pl.submit(1, np.arange(4, dtype=np.int32),
              deadline_s=time.monotonic() - 1.0)
    shed = pl.pump()
    assert [(r.rid, r.status) for r in shed] == [(1, "expired")]
    assert not any(r.rid == 1 for r in pl.drain())


def test_pipeline_default_slo_applies_to_submissions():
    g, x, layers, params, _ = _fixture()
    cfg = ServingConfig(batch_size=16, default_slo_s=120.0)
    pl = ServingPipeline(GNNServingEngine(g, x, layers, params, cfg))
    pl.submit(0, np.arange(3, dtype=np.int32))
    assert pl.batcher.queue[0].deadline_s is not None
    pl.submit(1, np.arange(3, dtype=np.int32), deadline_s=None, slo_s=None)
    assert pl.batcher.queue[1].deadline_s is not None
    assert all(r.status == "ok" for r in pl.drain())


# ------------------------------------------------------- backpressure
def test_pipeline_bounds_inflight_to_depth():
    """The pump never holds more than `depth` batches in flight, however
    deep the backlog — extraction-pool saturation backpressures
    admission instead of queueing unbounded extractions."""
    g, x, layers, params, _ = _fixture()
    cfg = ServingConfig(batch_size=4, pipeline_depth=2, extract_workers=2,
                        adaptive_batching=False)
    pl = ServingPipeline(GNNServingEngine(g, x, layers, params, cfg))
    for rid, ids in _requests(n=30):
        pl.submit(rid, ids)
    # pump repeatedly WITHOUT completing: in-flight must clamp at depth
    for _ in range(5):
        pl.pump()
        assert len(pl.inflight) <= 2
    assert pl.stats["inflight_hwm"] == 2
    assert len(pl.drain()) == 30
    pl.close()


# ------------------------------------------------- pipeline equivalence
def test_pipeline_matches_sync_engine_on_fixed_traffic():
    """Async pipelined serving returns bit-comparable outputs to the
    synchronous loop on identical traffic (no cache, so every batch
    runs the model)."""
    g, x, layers, params, cfg = _fixture()
    reqs = _requests()
    sync = GNNServingEngine(g, x, layers, params, cfg)
    for rid, ids in reqs:
        sync.submit(rid, ids)
    want = {r.rid: r.outputs for r in sync.drain()}

    acfg = ServingConfig(batch_size=16, pipeline_depth=3,
                         extract_workers=2, adaptive_batching=True)
    pl = ServingPipeline(GNNServingEngine(g, x, layers, params, acfg))
    for rid, ids in reqs:
        pl.submit(rid, ids)
    got = {r.rid: r.outputs for r in pl.drain()}
    assert set(got) == set(want)
    for rid in want:
        np.testing.assert_allclose(got[rid], want[rid],
                                   rtol=2e-5, atol=2e-5)
    pl.close()


def test_engine_step_drain_are_pipeline_wrappers():
    """The engine's historical sync API now runs through an inline
    depth-1 pipeline — same responses, and the compat pipeline's
    telemetry confirms it carried the batches."""
    g, x, layers, params, cfg = _fixture()
    eng = GNNServingEngine(g, x, layers, params, cfg)
    eng.submit(0, np.arange(5, dtype=np.int32))
    res = eng.step()
    assert len(res) == 1 and res[0].status == "ok"
    assert eng._compat is not None
    assert eng._compat.stats["pumped_batches"] == 1
    assert eng._compat.pool is None          # inline: no worker threads


# ------------------------------------------------------- replication
def test_replicated_round_robin_balances_evenly():
    g, x, layers, params, cfg = _fixture()
    srv = ReplicatedServer(g, x, layers, params, replicas=3, config=cfg,
                           balancer="round_robin")
    reqs = _requests(n=30)
    for rid, ids in reqs:
        srv.submit(rid, ids)
    assert srv.routed.tolist() == [10, 10, 10]
    res = srv.drain()
    assert sorted(r.rid for r in res) == sorted(r for r, _ in reqs)
    srv.close()


def test_replicated_least_outstanding_tracks_load():
    """least_outstanding routes around a replica with a deep queue."""
    g, x, layers, params, cfg = _fixture()
    srv = ReplicatedServer(g, x, layers, params, replicas=2, config=cfg,
                           balancer="least_outstanding")
    srv.pipelines[0].submit(999, np.arange(64, dtype=np.int32))  # preload
    for rid, ids in _requests(n=8):
        srv.submit(rid, ids)
    assert srv.routed[1] > srv.routed[0]
    srv.drain()
    srv.close()


def test_replicated_hub_affinity_pins_hub_to_one_replica():
    """Every request targeting a pinned hub lands on the same replica."""
    g, x, layers, params, _ = _fixture()
    cfg = ServingConfig(batch_size=16, cache_capacity=64)
    srv = ReplicatedServer(g, x, layers, params, replicas=2, config=cfg,
                           balancer="hub_affinity")
    hub = int(np.argmax(g.degrees()))
    assert hub in srv.engines[0].cache.pinned_ids
    picks = {srv.submit(100 + i, np.array([hub], np.int32))
             for i in range(6)}
    assert len(picks) == 1
    srv.drain()
    srv.close()


def test_replicated_outputs_match_single_engine():
    g, x, layers, params, cfg = _fixture()
    reqs = _requests(n=12)
    single = GNNServingEngine(g, x, layers, params, cfg)
    for rid, ids in reqs:
        single.submit(rid, ids)
    want = {r.rid: r.outputs for r in single.drain()}
    srv = ReplicatedServer(g, x, layers, params, replicas=2, config=cfg)
    for rid, ids in reqs:
        srv.submit(rid, ids)
    got = {r.rid: r.outputs for r in srv.drain()}
    assert set(got) == set(want)
    for rid in want:
        np.testing.assert_allclose(got[rid], want[rid],
                                   rtol=2e-5, atol=2e-5)
    srv.close()


# ---------------------------------------------------- workload generator
def test_workload_trace_is_deterministic():
    g, *_ = _fixture()
    for shape in ("constant", "diurnal", "flash_crowd", "hub_storm"):
        s = WorkloadSpec(n_requests=40, duration_s=2.0, shape=shape,
                         seed=7)
        t1, t2 = (make_trace(s, g.degrees()) for _ in range(2))
        for a, b in zip(t1, t2):
            assert a.t_offset_s == b.t_offset_s
            np.testing.assert_array_equal(a.vertex_ids, b.vertex_ids)


def test_workload_flash_crowd_spikes_the_middle():
    g, *_ = _fixture()
    spec = WorkloadSpec(n_requests=400, duration_s=10.0,
                        shape="flash_crowd", burst_factor=6.0,
                        burst_frac=0.2, seed=1)
    t = np.array([r.t_offset_s for r in make_trace(spec, g.degrees())])
    mid = ((t >= 4.0) & (t <= 6.0)).sum()
    # 20% of the window at 6x rate vs 80% at 1x -> ~60% of arrivals
    assert mid / t.size > 0.4
    assert t.min() >= 0.0 and t.max() <= 10.0


def test_workload_hub_storm_targets_hubs_in_burst_window():
    g, *_ = _fixture()
    spec = WorkloadSpec(n_requests=200, duration_s=10.0,
                        shape="hub_storm", storm_hubs=8, seed=2)
    trace = make_trace(spec, g.degrees())
    order = np.argsort(-g.degrees(), kind="stable")
    hubs = set(order[:8].tolist())
    burst = [r for r in trace if 4.0 <= r.t_offset_s <= 6.0]
    assert burst
    for r in burst:
        assert set(r.vertex_ids.tolist()) <= hubs


def test_workload_replay_closed_serves_everything():
    g, x, layers, params, cfg = _fixture(cache_capacity=64)
    pl = ServingPipeline(GNNServingEngine(g, x, layers, params, cfg))
    spec = WorkloadSpec(n_requests=40, duration_s=0.5, shape="diurnal",
                        seed=4)
    res = replay_closed(pl, make_trace(spec, g.degrees()), pump_every=4)
    assert sorted(r.rid for r in res if r.status == "ok") == list(range(40))
    pl.close()


# -------------------------------------------------------- cache warm-fill
def test_warm_fill_precomputes_pinned_hubs():
    """With warm_cache on, the pinned hub region is served from cache on
    first touch — zero subgraph extractions for a hub-only request."""
    g, x, layers, params, _ = _fixture()
    cfg = ServingConfig(batch_size=16, cache_capacity=64, warm_cache=True,
                        warm_cache_max=16)
    eng = GNNServingEngine(g, x, layers, params, cfg)
    assert eng.stats["warm_filled"] == 16
    eng.reset_telemetry()
    hub = int(np.argmax(g.degrees()))
    eng.submit(0, np.array([hub], np.int32))
    res = eng.drain()
    assert len(res) == 1
    assert eng.stats["subgraphs"] == 0              # pure cache hit
    assert eng.cache.stats["pinned_hits"] == 1


def test_warm_fill_matches_cold_inference():
    g, x, layers, params, _ = _fixture()
    cold = GNNServingEngine(g, x, layers, params,
                            ServingConfig(batch_size=16))
    warm = GNNServingEngine(
        g, x, layers, params,
        ServingConfig(batch_size=16, cache_capacity=64, warm_cache=True,
                      warm_cache_max=8))
    hubs = np.argsort(-g.degrees(), kind="stable")[:4].astype(np.int32)
    cold.submit(0, hubs)
    warm.submit(0, hubs)
    np.testing.assert_allclose(warm.drain()[0].outputs,
                               cold.drain()[0].outputs,
                               rtol=2e-5, atol=2e-5)


# ------------------------------------- config unification (shim removed)
def test_serving_config_embeds_engn_config():
    from repro.core.engn import EnGNConfig
    cfg = ServingConfig(engn=EnGNConfig(in_dim=0, out_dim=0,
                                        device_budget_bytes=123,
                                        ring_shards=2,
                                        streaming_mode="callback",
                                        tile_value_dtype="int8"))
    # execution knobs live on the embedded config, nowhere else
    assert cfg.engn.device_budget_bytes == 123
    assert cfg.engn.ring_shards == 2
    assert cfg.engn.streaming_mode == "callback"
    assert cfg.engn.tile_value_dtype == "int8"


def test_serving_config_deprecated_mirrors_are_gone():
    """The one-release write-through shim was removed: the old mirror
    names are unknown fields (TypeError), not silent no-ops, and the
    resolved-mirror attributes no longer exist on instances."""
    for kw in ("device_budget_bytes", "ring_shards",
               "tiled_streaming_mode", "tiled_value_dtype"):
        with pytest.raises(TypeError):
            ServingConfig(**{kw: 1})
    cfg = ServingConfig()
    for name in ("device_budget_bytes", "ring_shards",
                 "tiled_streaming_mode", "tiled_value_dtype"):
        assert not hasattr(cfg, name)
    assert cfg.engn.device_budget_bytes is None


def test_reset_telemetry_alias_is_consistent():
    """reset_telemetry is the primary name on both engine and batcher;
    reset_stats stays as the batcher's historical alias."""
    b = GNNBatcher(_echo_infer, batch_size=4)
    b.submit(Request(0, np.arange(3, dtype=np.int32)))
    b.drain()
    assert b.stats["requests"] == 1
    b.reset_telemetry()
    assert b.stats["requests"] == 0
    b.submit(Request(1, np.arange(3, dtype=np.int32)))
    b.drain()
    b.reset_stats()                        # alias, same semantics
    assert b.stats["requests"] == 0


# ------------------------------------------------- PreparedPlan round-trip
@pytest.mark.parametrize("backend", ["segment", "blocked", "fused",
                                     "tiled", "ring"])
def test_prepared_plan_round_trip(backend):
    """Every prepare_* entry point returns a typed `PreparedPlan` whose
    typed attributes agree with the carrier's meta block and which
    drives `apply` directly; the removed dict view stays removed."""
    import jax
    import jax.numpy as jnp
    from repro.core.engn import prepare_graph
    from repro.core.models import make_gnn
    from repro.core.plan import PreparedPlan
    from repro.graphs.generate import rmat_graph, random_features

    g = rmat_graph(96, 700, seed=0).gcn_normalized()
    x = random_features(96, 8, seed=1)
    layer = make_gnn("gcn", 8, 4, backend=backend, tile=16)
    if backend == "ring":
        layer.cfg.ring_shards = 2
    elif backend == "tiled":
        layer.cfg.tile = 32
        layer.cfg.device_budget_bytes = 200_000
    plan = prepare_graph(g, layer.cfg)
    assert isinstance(plan, PreparedPlan)
    assert plan.backend == backend
    assert plan.n == 96
    # the MutableMapping view is gone: key access raises, the carrier
    # and typed attributes are the supported surfaces
    with pytest.raises(TypeError):
        plan["backend"]
    assert plan.as_dict() is plan.carrier
    assert plan.carrier["backend"] == backend
    if backend == "segment":
        assert plan.tile_format is None and plan.footprint_bytes == 0
    else:
        assert plan.tile_format in ("dense", "packed")
        assert plan.footprint_bytes > 0
        assert plan.meta                     # the meta block resolves
    if backend == "tiled":
        assert plan.streaming_mode in ("chunk_queue", "callback")
    else:
        assert plan.streaming_mode is None
    y = layer.apply(layer.init(jax.random.key(0)), plan, jnp.asarray(x))
    assert np.asarray(y).shape == (96, 4)
