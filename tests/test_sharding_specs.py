"""Sharding-spec construction: divisibility fallbacks, logical-axis rules,
dry-run input specs.  Single-device meshes (no forced device count here —
smoke tests must see 1 device; the real meshes are exercised by the
dry-run deliverable)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke
from repro.distributed.sharding import (Constrainer, batch_pspec,
                                        make_rules, param_pspecs)
from repro.launch import specs as SP
from repro.launch.mesh import single_device_mesh
from repro.nn.param import ParamSpec, spec_to_pspec


def test_spec_to_pspec_divisibility_fallback():
    ms = {"data": 16, "model": 16}
    # divides: sharded
    s = ParamSpec((256, 64), ("embed", "mlp"))
    assert spec_to_pspec(s, ms) == P("data", "model")
    # does not divide: replicated on that dim
    s2 = ParamSpec((100, 64), ("embed", "mlp"))
    assert spec_to_pspec(s2, ms) == P(None, "model")
    # logical axis missing from rules: replicated
    s3 = ParamSpec((256,), (None,))
    assert spec_to_pspec(s3, ms) == P(None)


class _FakeMesh:
    """Duck-typed mesh: batch_pspec only reads axis_names/devices.shape."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


def test_batch_pspec_shape_fallback():
    """The long_500k regression: batch=1 must not shard over data=16."""
    mesh = _FakeMesh((16, 16), ("data", "model"))
    rules = {"batch": "data", "seq": "model"}
    assert batch_pspec(mesh, 2, rules=rules, shape=(1, 1)) == P(None, None)
    sp = batch_pspec(mesh, 2, seq_axis=1, rules=rules, shape=(128, 32768))
    assert sp == P("data", "model")
    # batch divides but seq does not
    sp2 = batch_pspec(mesh, 2, seq_axis=1, rules=rules, shape=(128, 100))
    assert sp2 == P("data", None)


def test_constrainer_replicates_non_dividing():
    mesh = single_device_mesh()
    sc = Constrainer(mesh)
    x = jnp.zeros((3, 5))
    y = sc(x, ("batch", "seq"))           # 1x1 mesh: all divides
    assert y.shape == x.shape


def test_make_rules_drops_missing_axes():
    mesh = single_device_mesh()           # axes: data, model
    rules = make_rules(mesh)
    assert rules["batch"] == ("data",)    # "pod" dropped
    assert rules["embed"] == "data"
    rules_ns = make_rules(mesh, seq_sharded=False)
    assert rules_ns["seq"] is None


@pytest.mark.parametrize("arch", ["granite_3_2b", "jamba_1_5_large_398b",
                                  "seamless_m4t_large_v2"])
def test_param_pspecs_tree_matches_params(arch):
    cfg = get_smoke(arch)
    mesh = single_device_mesh()
    ps = param_pspecs(cfg, mesh)
    from repro.nn import transformer as T
    ab = T.abstract_params(cfg)
    # same tree structure
    assert jax.tree.structure(ps) == jax.tree.structure(
        jax.tree.map(lambda x: 0, ab))


def test_train_batch_specs_shapes():
    cfg = get_config("granite_3_2b")
    b = SP.train_batch_specs(cfg, 4096, 256)
    assert b["tokens"].shape == (256, 4096)
    assert b["labels"].dtype == jnp.int32

    vlm = get_config("llama_3_2_vision_11b")
    bv = SP.train_batch_specs(vlm, 128, 4)
    assert "image_embeds" in bv["extras"]
    assert bv["extras"]["image_embeds"].shape[0] == 4

    ed = get_config("seamless_m4t_large_v2")
    be = SP.train_batch_specs(ed, 128, 4)
    assert be["extras"]["frames"].shape == (4, 128, ed.d_model)


def test_decode_state_specs_cover_families():
    for arch, keys in [("granite_3_2b", {"k", "v"}),
                       ("falcon_mamba_7b", {"conv", "ssm"}),
                       ("jamba_1_5_large_398b", {"k", "v", "conv", "ssm"}),
                       ("llama_3_2_vision_11b", {"k", "v", "mk", "mv"})]:
        cfg = get_config(arch)
        st = SP.decode_state_specs(cfg, 4, 64)
        leaf_names = set()
        for slot in st["layers"].values():
            leaf_names |= set(slot.keys())
        assert keys <= leaf_names, (arch, leaf_names)


def test_decode_state_pspecs_no_crash():
    mesh = single_device_mesh()
    for arch in ("granite_3_2b", "falcon_mamba_7b"):
        cfg = get_config(arch)
        st = SP.decode_state_specs(cfg, 4, 64)
        ps = SP.decode_state_pspecs(cfg, st, mesh)
        assert jax.tree.structure(ps, is_leaf=lambda x: isinstance(x, P))


def test_elastic_mesh_always_valid():
    for n in (1, 2, 3, 6, 16):
        # can't make more devices than exist; just exercise the divisor math
        mp = 16
        m = min(mp, n)
        while n % m:
            m //= 2
        assert n % max(m, 1) == 0
