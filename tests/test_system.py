"""End-to-end system behaviour: the paper's full pipeline (graph in ->
preprocess -> tiled EnGN inference -> results out) plus the dry-run and
roofline machinery on a small in-process scale."""
import json
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.engn import prepare_graph
from repro.core.models import make_gnn_stack, init_stack, apply_stack
from repro.graphs.degree import (apply_vertex_permutation,
                                 degree_sort_permutation, permute_features,
                                 unpermute_features)
from repro.graphs.generate import make_dataset, random_features
from repro.launch.analysis import (Roofline, model_flops_estimate,
                                   parse_collective_bytes)
from repro.launch.jaxpr_cost import traced_cost


def test_full_engn_pipeline_cora_scale():
    """Cora-shaped graph through the production path: degree relabelling
    (TPU-DAVC) -> GCN normalisation -> blocked RER-SpMM backend -> 2-layer
    GCN -> unpermute.  Must equal the naive segment path exactly."""
    g, f, labels = make_dataset("cora", seed=0)
    f = 64                      # keep the CPU run fast
    x = random_features(g.num_vertices, f, seed=1)

    # ---- optimised path (the EnGN production flow)
    perm = degree_sort_permutation(g)
    g_opt = apply_vertex_permutation(g, perm).gcn_normalized()
    x_opt = permute_features(x, perm)
    layers = make_gnn_stack("gcn", [f, 32, labels], backend="blocked",
                            tile=128)
    params = init_stack(layers, jax.random.key(0))
    gd = prepare_graph(g_opt, layers[0].cfg)
    y_opt = np.asarray(apply_stack(layers, params, gd,
                                   jnp.asarray(x_opt)))
    y_opt = unpermute_features(y_opt, perm)

    # ---- reference path (edge-centric Algorithm 1)
    g_ref = g.gcn_normalized()
    ref_layers = make_gnn_stack("gcn", [f, 32, labels], backend="segment")
    gd_ref = prepare_graph(g_ref, ref_layers[0].cfg)
    y_ref = np.asarray(apply_stack(ref_layers, params, gd_ref,
                                   jnp.asarray(x)))

    np.testing.assert_allclose(y_opt, y_ref, rtol=1e-3, atol=1e-3)
    assert y_opt.shape == (g.num_vertices, labels)


def test_collective_parser_counts_bytes():
    hlo = """
ENTRY %main (a: f32[16]) -> f32[16] {
  %ag = f32[64]{0} all-gather(f32[16]{0} %a), replica_groups={}
  %ar = f32[16]{0} all-reduce(f32[16]{0} %a), to_apply=%add
}
"""
    got = parse_collective_bytes(hlo)
    assert got.get("all-gather") == 64 * 4
    assert got.get("all-reduce") == 16 * 4


def test_collective_parser_while_multiplier():
    """Collectives inside a scanned (while) body count trip_count times."""
    hlo = """
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), to_apply=%add
}
%cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(12)
  %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%cond, body=%body
}
"""
    got = parse_collective_bytes(hlo)
    assert got.get("all-reduce") == 8 * 4 * 12


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12, hbm_bytes=819e9 * 2, collective_bytes=0,
                 collectives={}, chips=1)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.roofline_fraction() - 0.5) < 1e-9
    d = r.as_dict()
    assert d["dominant"] == "memory"


def test_traced_cost_counts_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = traced_cost(f, a, b)
    assert c.flops == 2 * 128 * 256 * 64


def test_traced_cost_scan_multiplies():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = traced_cost(f, x)
    assert c.flops == 5 * 2 * 32 * 32 * 32


def test_model_flops_estimate_moe_discount():
    from repro.configs import get_config
    dense = get_config("qwen2_72b")
    moe = get_config("moonshot_v1_16b_a3b")
    fd = model_flops_estimate(dense, "train", 128, 2)
    fm = model_flops_estimate(moe, "train", 128, 2)
    # moonshot activates ~3B of 16B params; flops must reflect that
    from repro.nn.transformer import param_count
    assert fm < 6 * param_count(moe) * 256
    assert fd == pytest.approx(6 * param_count(dense) * 256, rel=1e-6)


def test_dryrun_cell_records_exist_and_complete():
    """The dry-run deliverable: all 40 cells x 2 meshes accounted for."""
    import glob
    import itertools
    from pathlib import Path
    from repro.configs import ARCH_IDS
    from repro.launch import specs as SP

    out = Path("experiments/dryrun")
    if not out.exists():
        pytest.skip("dry-run artifacts not generated in this checkout")
    have = {}
    for fn in glob.glob(str(out / "*.json")):
        r = json.loads(Path(fn).read_text())
        have[(r["arch"], r["shape"], r["mesh"])] = r["status"]
    for arch, shape, mesh in itertools.product(
            ARCH_IDS, SP.SHAPES, ["single", "multi"]):
        st = have.get((arch, shape, mesh))
        assert st in ("ok", "skipped"), (arch, shape, mesh, st)
        # skips only where the shape is inapplicable
        from repro.configs import get_config
        ok, _ = SP.shape_applicable(get_config(arch), shape)
        assert (st == "ok") == ok, (arch, shape, mesh, st)
