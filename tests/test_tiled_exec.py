"""Out-of-core tiled executor (core/tiled.py, DESIGN.md C7): tile-boundary
correctness against the segment reference, the device-budget spill, and
the enwiki-scale acceptance path.  Property-based via hypothesis (vendored
fallback on clean checkouts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # clean checkout: vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.davc import simulate_davc, simulate_davc_reference
from repro.core.engn import (DeviceBudgetExceeded, EnGNConfig,
                             prepare_graph, segment_aggregate)
from repro.core.models import (apply_stack, init_stack, make_gnn,
                               make_gnn_stack)
from repro.core.tiled import (TiledExecutor, dense_footprint_bytes,
                              fit_tile_plan)
from repro.graphs.format import COOGraph
from repro.graphs.generate import (DATASET_STATS, make_dataset,
                                   random_features, rmat_graph)


def _int_graph(n, e, seed, self_loop_heavy=False):
    """Deduplicated integer-weighted graph: float sums of small integers
    are exact in fp32 regardless of reduction order, so tiled execution
    must match the segment reference *bit-for-bit*.  Dedup matters for
    max: tiles merge multi-edges by summation before max sees them."""
    g = rmat_graph(n, e, seed=seed)
    src, dst = g.src, g.dst
    if self_loop_heavy:
        loops = np.arange(n, dtype=np.int32)
        src = np.concatenate([src, loops, loops])
        dst = np.concatenate([dst, loops, loops])
    uniq = np.unique(np.stack([src, dst]), axis=1)
    rng = np.random.default_rng(seed)
    val = rng.integers(1, 4, uniq.shape[1]).astype(np.float32)
    return COOGraph(n, uniq[0].astype(np.int32), uniq[1].astype(np.int32),
                    val)


def _int_features(n, f, seed):
    rng = np.random.default_rng(seed + 17)
    return rng.integers(-3, 4, (n, f)).astype(np.float32)


def _segment_ref(g, x, op):
    ev = jnp.asarray(x)[jnp.asarray(g.src)] * jnp.asarray(g.val)[:, None]
    return np.asarray(segment_aggregate(ev, jnp.asarray(g.dst),
                                        g.num_vertices, op))


# ---------------------------------------------------- tile boundaries
# (the generic streamed-vs-segment parity property moved to
# tests/test_backend_matrix.py, which sweeps every backend x format x
# op x graph shape from one set of shared fixtures)
@settings(max_examples=6, deadline=None)
@given(n=st.integers(8, 60), e=st.integers(1, 300), seed=st.integers(0, 4),
       op=st.sampled_from(["sum", "max"]),
       order=st.sampled_from(["column", "row"]))
def test_tiled_kernel_impls_match(n, e, seed, op, order):
    """The chunk step routed through the rer_spmm kernel dispatcher
    (XLA path, and Pallas in interpret mode) equals the einsum step,
    in both sweep orders."""
    g = _int_graph(n, e, seed)
    x = _int_features(n, 5, seed)
    want = _segment_ref(g, x, op)
    for impl in ("xla", "pallas"):
        ex = TiledExecutor(g, tile=8, chunk=4, impl=impl)
        got = ex.aggregate(x, op, order=order)
        assert np.array_equal(got, want), (impl, order)


def test_tiled_empty_graph_and_empty_rows():
    g = COOGraph(10, np.array([0], np.int32), np.array([9], np.int32),
                 np.array([2.0], np.float32))
    x = _int_features(10, 4, 0)
    ex = TiledExecutor(g, tile=3, chunk=2)
    for op in ("sum", "max", "mean"):
        got = ex.aggregate(x, op)
        assert np.array_equal(got, _segment_ref(g, x, op)), op


def test_double_buffer_off_same_results_and_stats():
    g = _int_graph(60, 400, seed=1)
    x = _int_features(60, 6, 1)
    # pin the callback loop: double buffering is a property of the
    # per-chunk staging pipeline the chunk-queue route replaces
    ex_db = TiledExecutor(g, tile=16, chunk=2, double_buffer=True,
                          streaming_mode="callback")
    ex_sq = TiledExecutor(g, tile=16, chunk=2, double_buffer=False,
                          streaming_mode="callback")
    a = ex_db.aggregate(x, "sum", order="column")
    b = ex_sq.aggregate(x, "sum", order="column")
    assert np.array_equal(a, b)
    assert ex_db.stats.steps == ex_sq.stats.steps
    assert ex_db.stats.h2d_tile_bytes > 0
    assert ex_db.stats.d2h_bytes > 0
    # the S-shape snake revisits the boundary source interval: reuse hits
    assert ex_db.stats.x_reuse_hits > 0


def test_row_order_spills_more_than_column():
    """Table 3: row-major streams a partial accumulator out per tile
    (Q^2 writes), column-major flushes each interval once (Q writes)."""
    g = _int_graph(100, 900, seed=2)
    x = _int_features(100, 8, 2)
    # pin the callback loop: accumulator spill traffic is a property
    # of the per-chunk schedule, not of the device-resident queue
    col = TiledExecutor(g, tile=16, chunk=1, streaming_mode="callback")
    row = TiledExecutor(g, tile=16, chunk=1, streaming_mode="callback")
    a = col.aggregate(x, "sum", order="column")
    b = row.aggregate(x, "sum", order="row")
    assert np.array_equal(a, b)
    assert row.stats.d2h_bytes > col.stats.d2h_bytes


# ---------------------------------------------------- budget / spill
def test_fit_tile_plan_shrinks_to_budget():
    tile, chunk = fit_tile_plan(None, 128)
    assert (tile, chunk) == (256, 8)
    tile, chunk = fit_tile_plan(200_000, 300, tile=256, chunk=8)
    assert 4 * 2 * (chunk * tile * tile + chunk * tile * 300) <= 200_000
    with pytest.raises(DeviceBudgetExceeded):
        fit_tile_plan(10, 300)


def test_prepare_graph_budget_spills_and_raises():
    g = rmat_graph(200, 2000, seed=0).gcn_normalized()
    strict = EnGNConfig(in_dim=32, out_dim=16, backend="segment",
                        device_budget_bytes=30_000, auto_spill=False)
    with pytest.raises(DeviceBudgetExceeded):
        prepare_graph(g, strict)
    spill = EnGNConfig(in_dim=32, out_dim=16, backend="segment",
                       device_budget_bytes=30_000)
    gd = prepare_graph(g, spill)
    assert gd.backend == "tiled"
    # the fitted streaming step respects the budget
    meta = gd.meta
    assert meta["tile"] <= 256 and meta["chunk"] >= 1


def test_enwiki_scale_runs_tiled_where_dense_fails():
    """Acceptance: a 2-layer GCN at DATASET_STATS['enwiki'] feature dims
    under a budget that makes every dense path fail; results match the
    (unbudgeted) segment reference on the tier-1-sized graph."""
    v, e, f, labels = DATASET_STATS["enwiki"]
    assert (v, e, f) == (3_600_000, 276_000_000, 300)
    # tier-1-sized stand-in with the real enwiki feature/label dims
    g, _, _ = make_dataset("enwiki", seed=0, max_vertices=3000,
                           max_edges=24_000)
    gn = g.gcn_normalized()
    x = random_features(g.num_vertices, f, seed=0)
    budget = 1_000_000           # 1 MB: far below any dense footprint
    for backend in ("segment", "blocked", "fused", "ring"):
        assert dense_footprint_bytes(gn.num_vertices, gn.num_edges, f, 64,
                                     backend) > budget
        # ring_shards pinned: the ring budget is per shard, so the gate
        # depends on the ring size (the multi-device CI job sees 8)
        strict = EnGNConfig(in_dim=f, out_dim=64, backend=backend,
                            ring_shards=1, device_budget_bytes=budget,
                            auto_spill=False)
        with pytest.raises(DeviceBudgetExceeded):
            prepare_graph(gn, strict)

    layers = make_gnn_stack("gcn", [f, 64, labels], backend="tiled")
    for layer in layers:
        layer.cfg.device_budget_bytes = budget
    params = init_stack(layers, jax.random.key(0))
    gd = prepare_graph(gn, layers[0].cfg, out_dim=64)
    assert gd.backend == "tiled"
    y = apply_stack(layers, params, gd, x)
    assert y.shape == (g.num_vertices, labels)
    assert np.isfinite(y).all()

    ref_layers = make_gnn_stack("gcn", [f, 64, labels], backend="segment")
    ref = np.asarray(apply_stack(ref_layers, params,
                                 prepare_graph(gn, ref_layers[0].cfg),
                                 jnp.asarray(x)))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


def test_tiled_layer_max_and_mean_models():
    """Non-sum models through the streamed layer path: GS-Pool (max
    extraction/update overrides) and a mean-aggregating GCN config."""
    g = _int_graph(80, 500, seed=3)
    x = random_features(80, 12, seed=3)
    for model, op in (("gs_pool", "max"), ("gcn", "mean")):
        seg = make_gnn(model, 12, 8, backend="segment")
        seg.cfg.aggregate_op = op
        til = make_gnn(model, 12, 8, backend="tiled", tile=16)
        til.cfg.aggregate_op = op
        params = seg.init(jax.random.key(4))
        want = np.asarray(seg.apply(params, prepare_graph(g, seg.cfg),
                                    jnp.asarray(x)))
        got = til.apply(params, prepare_graph(g, til.cfg), x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_staged_models_spill_to_the_streamed_executor():
    """R-GCN / Gated-GCN used to override apply() and fence the spill
    with NotImplementedError; under the stage contract (DESIGN.md C10)
    the auto-spill streams them like any other model — the budgeted
    result must match the unbudgeted segment reference, and a serving
    engine with a budget must accept such stacks at construction."""
    g = rmat_graph(60, 400, seed=0).gcn_normalized()
    x = random_features(60, 8, seed=0)
    gated = make_gnn("gated_gcn", 8, 4)
    gated.cfg.device_budget_bytes = 10_000     # force the spill
    params = gated.init(jax.random.key(0))
    gd = prepare_graph(g, gated.cfg)
    assert gd.backend == "tiled"
    got = np.asarray(gated.apply(params, gd, x))
    seg = make_gnn("gated_gcn", 8, 4)
    want = np.asarray(seg.apply(params, prepare_graph(g, seg.cfg),
                                jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    from repro.core.engn import EnGNConfig
    from repro.serving.engine import GNNServingEngine, ServingConfig
    layers = [make_gnn("gated_gcn", 8, 4)]
    ps = [layers[0].init(jax.random.key(1))]
    eng = GNNServingEngine(
        g, x, layers, ps,
        ServingConfig(engn=EnGNConfig(in_dim=0, out_dim=0,
                                      device_budget_bytes=10_000)))
    assert eng is not None


def test_effective_chunk_refuses_oversized_store_tile():
    """A store built for a narrow dim must refuse (not silently exceed
    the budget) when asked to stream a much wider feature dim."""
    g = rmat_graph(100, 600, seed=0).gcn_normalized()
    ex = TiledExecutor(g, tile=32, chunk=2, budget_bytes=40_000,
                       dim_hint=8)
    assert ex.effective_chunk(8) >= 1
    with pytest.raises(DeviceBudgetExceeded, match="rebuild"):
        ex.effective_chunk(4096)


# ---------------------------------------------------- serving fallback
def test_serving_falls_back_to_tiled_instead_of_ooming():
    from repro.serving.engine import GNNServingEngine, ServingConfig
    g = rmat_graph(300, 2500, seed=0).gcn_normalized()
    x = random_features(300, 16, seed=1)
    layers = make_gnn_stack("gcn", [16, 8, 4])
    params = init_stack(layers, jax.random.key(0))
    reqs = [np.arange(25, dtype=np.int32), np.array([5, 200], np.int32)]

    ref_eng = GNNServingEngine(g, x, layers, params,
                               ServingConfig(batch_size=8))
    for i, ids in enumerate(reqs):
        ref_eng.submit(i, ids)
    want = {r.rid: r.outputs for r in ref_eng.drain()}

    eng = GNNServingEngine(g, x, layers, params,
                           ServingConfig(batch_size=8, tiled_tile=32,
                                         engn=EnGNConfig(
                                             in_dim=0, out_dim=0,
                                             device_budget_bytes=50_000)))
    for i, ids in enumerate(reqs):
        eng.submit(i, ids)
    got = {r.rid: r.outputs for r in eng.drain()}
    assert eng.stats["tiled_batches"] > 0
    for rid in want:
        np.testing.assert_allclose(got[rid], want[rid],
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------- DAVC vectorisation
@settings(max_examples=15, deadline=None)
@given(n=st.integers(5, 300), e=st.integers(1, 2500),
       seed=st.integers(0, 8), lines=st.integers(1, 64),
       frac=st.floats(0.0, 1.0))
def test_simulate_davc_matches_reference(n, e, seed, lines, frac):
    """The vectorised stack-distance LRU equals the pointer-chasing
    OrderedDict oracle exactly."""
    g = rmat_graph(n, e, seed=seed)
    assert simulate_davc(g, lines, frac) == pytest.approx(
        simulate_davc_reference(g, lines, frac), abs=1e-12)


def test_simulate_davc_scales():
    g = rmat_graph(50_000, 400_000, seed=0)
    hr = simulate_davc(g, 1024, 0.5)
    assert 0.0 < hr < 1.0
