"""Training substrate: optimizer, schedules, grad accumulation,
gradient compression (error feedback), GNN end-to-end loss descent."""
import numpy as np
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # clean checkout: vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_smoke
from repro.core.models import make_gnn_stack, init_stack, apply_stack
from repro.core.engn import prepare_graph
from repro.distributed.compression import (compression_ratio,
                                           dequantize_int8,
                                           make_error_feedback_transform,
                                           quantize_int8)
from repro.graphs.generate import rmat_graph, random_features
from repro.nn import transformer as T
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      clip_by_global_norm, global_norm,
                                      init_opt_state)
from repro.training.schedule import cosine_schedule, wsd_schedule
from repro.training.train_lib import (make_grad_accum_train_step,
                                      make_train_step)


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    opt = init_opt_state(params)
    cfg = AdamWConfig(weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(cfg, g, opt, params, 0.05)
    assert float(loss(params)) < 1e-2


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(weight_decay=0.5)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    zeros = jax.tree.map(jnp.zeros_like, params)
    opt = init_opt_state(params)
    p2, _ = adamw_update(cfg, zeros, opt, params, 0.1)
    assert float(p2["w"][0, 0]) < 1.0     # decayed
    assert float(p2["b"][0]) == 1.0       # not decayed


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}    # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # below the limit: untouched
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])


# ---------------------------------------------------------------- schedules
def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(101)]
    assert lrs[0] == 0.0
    np.testing.assert_allclose(lrs[10], 1.0, rtol=1e-5)
    assert lrs[100] < 0.2
    assert all(a <= b + 1e-9 for a, b in zip(lrs[:10], lrs[1:11]))  # warmup up


def test_wsd_schedule_stable_phase():
    lrs = [float(wsd_schedule(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(101)]
    np.testing.assert_allclose(lrs[20], 1.0, rtol=1e-6)   # stable
    np.testing.assert_allclose(lrs[80], 1.0, rtol=1e-6)   # still stable
    assert lrs[100] < 0.05                                # decayed


# ------------------------------------------------------------- grad accum
def test_grad_accum_matches_full_batch():
    cfg = get_smoke("granite_3_2b")
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                              jnp.int32),
    }
    step_full = make_train_step(cfg, q_chunk=8, loss_chunk=8)
    step_acc = make_grad_accum_train_step(cfg, micro_steps=2, q_chunk=8,
                                          loss_chunk=8)
    opt = init_opt_state(params)
    p1, _, m1 = jax.jit(step_full)(params, opt, batch)
    p2, _, m2 = jax.jit(step_acc)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    l1, l2 = jax.tree.leaves(p1), jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_lm_loss_decreases():
    """A few hundred steps on a tiny LM must reduce loss on a fixed batch."""
    cfg = get_smoke("minicpm_2b")
    params = T.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                              jnp.int32),
    }
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=5,
                                   total_steps=60, q_chunk=8, loss_chunk=8))
    opt = init_opt_state(params)
    losses = []
    for _ in range(40):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::8]


# ------------------------------------------------------------- compression
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 100))
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6   # round-to-nearest bound


def test_error_feedback_accumulates_to_truth():
    """Sum of compressed gradients + final residual == sum of raw
    gradients: error feedback loses nothing over time."""
    transform, init_error = make_error_feedback_transform()
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
             for _ in range(20)]
    err = init_error(grads[0])
    total_comp = jnp.zeros(64)
    for g in grads:
        cg, err = transform(g, err)
        total_comp = total_comp + cg["w"]
    total_raw = sum(g["w"] for g in grads)
    np.testing.assert_allclose(np.asarray(total_comp + err["w"]),
                               np.asarray(total_raw), rtol=1e-4, atol=1e-4)


def test_compression_ratio_about_quarter():
    params = {"w": jnp.zeros((1024, 1024))}
    assert abs(compression_ratio(params) - 0.25) < 0.01


def test_train_step_with_compression_still_learns():
    cfg = get_smoke("granite_3_2b")
    params = T.init_params(cfg, jax.random.key(2))
    transform, init_error = make_error_feedback_transform()
    err = [init_error(params)]

    def grad_transform(grads):
        cg, err[0] = transform(grads, err[0])
        return cg

    step = make_train_step(cfg, peak_lr=3e-3, warmup=5, total_steps=50,
                           q_chunk=8, loss_chunk=8,
                           grad_transform=grad_transform)
    rng = np.random.default_rng(2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
    }
    opt = init_opt_state(params)
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# ------------------------------------------------------------- GNN training
def test_gnn_node_classification_learns():
    """End-to-end GNN training on the EnGN processing model."""
    g = rmat_graph(120, 900, seed=0).gcn_normalized()
    f, h, classes = 16, 32, 4
    layers = make_gnn_stack("gcn", [f, h, classes])
    params = init_stack(layers, jax.random.key(3))
    gd = prepare_graph(g, layers[0].cfg)
    x = jnp.asarray(random_features(g.num_vertices, f, seed=1))
    rng = np.random.default_rng(4)
    y = jnp.asarray(rng.integers(0, classes, g.num_vertices), jnp.int32)

    def loss_fn(ps):
        logits = apply_stack(layers, ps, gd, x)
        ll = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(ll, y[:, None], 1))

    opt = init_opt_state(params)
    cfg = AdamWConfig(weight_decay=0.0)
    step = jax.jit(lambda ps, o: (lambda lv, g: adamw_update(cfg, g, o, ps, 0.01) + (lv,))(*jax.value_and_grad(loss_fn)(ps)))
    l0 = float(loss_fn(params))
    for _ in range(150):
        params, opt, _ = step(params, opt)
    l1 = float(loss_fn(params))
    assert l1 < l0 - 0.3, (l0, l1)
