"""Dynamic graphs (graphs/updates.py + update_plan, DESIGN.md C14):
the insert/delete log's snapshot semantics, and the central property —
every incremental merge (`update_tile_store` / `update_packed_store` /
`TiledExecutor.apply_updates` / `update_plan`) is **bitwise** equal to
a from-scratch rebuild of the epoch graph, across blocked / tiled /
ring x dense / packed, including delete-to-empty tiles and
relation-typed edges.  Integer weights and features make fp32 sums
exact in any order, so "bitwise" is the honest bar, not a tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # clean checkout: vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.engn import (EnGNConfig, EnGNLayer, prepare_graph,
                             segment_aggregate, update_plan)
from repro.core.tiled import TiledExecutor
from repro.graphs.format import COOGraph
from repro.graphs.generate import rmat_graph
from repro.graphs.partition import build_tile_store, pack_tile_store
from repro.graphs.updates import (UpdateLog, update_packed_store,
                                  update_tile_store)
from repro.serving.cache import DegreeAwareCache

RING_SHARDS = min(len(jax.devices()), 8)


# ---------------------------------------------------- fixtures
def _int_graph(n, e, seed, relations=1):
    """Deduplicated integer-weighted graph (optionally relation-typed):
    small-int fp32 sums are exact in any reduction order, so every
    incremental path must match a fresh build bit-for-bit."""
    g = rmat_graph(n, e, seed=seed)
    uniq = np.unique(np.stack([g.src, g.dst]), axis=1)
    rng = np.random.default_rng(seed)
    val = rng.integers(1, 4, uniq.shape[1]).astype(np.float32)
    rel = (rng.integers(0, relations, uniq.shape[1]).astype(np.int32)
           if relations > 1 else None)
    return COOGraph(n, uniq[0].astype(np.int32), uniq[1].astype(np.int32),
                    val, rel, relations)


def _int_features(n, f, seed):
    rng = np.random.default_rng(seed + 17)
    return rng.integers(-3, 4, (n, f)).astype(np.float32)


def _random_epoch(log, seed, n_del, n_ins, grow=0):
    """Delete n_del random existing edges, insert n_ins random ones
    (into [0, n + grow)), snapshot.  Typed logs draw relation ids."""
    rng = np.random.default_rng(seed)
    g = log.graph
    r = g.num_relations
    if n_del and g.num_edges:
        pick = rng.choice(g.num_edges, min(n_del, g.num_edges),
                          replace=False)
        rel = g.rel[pick] if (r > 1 and g.rel is not None
                              and seed % 2 == 0) else None
        log.delete(g.src[pick], g.dst[pick], rel)
    if n_ins:
        hi = g.num_vertices + grow
        log.insert(rng.integers(0, hi, n_ins),
                   rng.integers(0, hi, n_ins),
                   rng.integers(1, 4, n_ins).astype(np.float32),
                   rng.integers(0, r, n_ins) if r > 1 else None)
    return log.snapshot()


def _assert_store_eq(a, b):
    """Field-by-field bitwise equality of two (Edge|Packed)TileStores."""
    assert type(a) is type(b)
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            assert va is not None and vb is not None, f.name
            assert va.dtype == vb.dtype, f.name
            assert np.array_equal(va, vb), f.name
        else:
            assert va == vb, f.name


def _merged_stores(store, packed, snap):
    new_store, delta = update_tile_store(store, snap.batch,
                                         snap.graph.num_vertices)
    new_packed = update_packed_store(packed, new_store, delta)
    return new_store, new_packed, delta


# ---------------------------------------------------- log semantics
def test_log_delete_cancels_earlier_insert():
    g = _int_graph(8, 10, 0)
    log = UpdateLog(g)
    log.insert(1, 2, 2.0)
    log.delete(1, 2)          # kills the pending insert and any base edge
    log.insert(1, 2, 3.0)     # logged after the delete: survives
    snap = log.snapshot()
    m = (snap.graph.src == 1) & (snap.graph.dst == 2)
    assert m.sum() == 1 and snap.graph.weights()[m][0] == 3.0
    assert log.epoch == 1 and log.pending == 0


def test_log_multi_edge_delete_and_touched_sets():
    src = np.array([0, 0, 3], np.int32)
    dst = np.array([1, 1, 2], np.int32)   # multi-edge at (0, 1)
    g = COOGraph(5, src, dst, np.array([1.0, 2.0, 3.0], np.float32))
    log = UpdateLog(g)
    log.delete(0, 1)
    snap = log.snapshot()
    assert snap.batch.num_deleted == 2        # both parallel edges die
    assert snap.batch.del_src.shape == (1,)   # one unique coordinate
    assert snap.graph.num_edges == 1
    assert snap.touched_dst.tolist() == [1]
    assert snap.touched_src.tolist() == [0]


def test_log_vertex_growth_and_validation():
    g = _int_graph(8, 10, 1)
    log = UpdateLog(g)
    log.insert(7, 12)                         # grows n to 13
    assert log.snapshot().graph.num_vertices == 13
    with pytest.raises(ValueError):
        log.insert(-1, 0)
    tg = _int_graph(8, 10, 1, relations=3)
    tlog = UpdateLog(tg)
    with pytest.raises(ValueError):
        tlog.insert(0, 1, rel=3)


def test_log_wildcard_delete_kills_all_relations():
    g = COOGraph(4, np.array([0, 0, 1], np.int32),
                 np.array([2, 2, 3], np.int32),
                 np.ones(3, np.float32),
                 np.array([0, 2, 1], np.int32), 3)
    log = UpdateLog(g)
    log.delete(0, 2)                          # rel=None: every relation
    snap = log.snapshot()
    assert snap.graph.num_edges == 1
    assert snap.graph.rel.tolist() == [1]


# ---------------------------------------------------- store merge parity
@settings(max_examples=12, deadline=None)
@given(n=st.integers(6, 120), e=st.integers(2, 500),
       seed=st.integers(0, 5), tile=st.integers(4, 33),
       relations=st.sampled_from([1, 1, 3]),
       grow=st.sampled_from([0, 0, 9]))
def test_property_store_merge_matches_rebuild(n, e, seed, tile,
                                              relations, grow):
    """Two epochs of random deletes + inserts (sometimes growing the
    vertex set past a tile-grid boundary, sometimes relation-typed):
    the merged EdgeTileStore and PackedTileStore must equal a fresh
    build/pack of the epoch graph field-for-field, bitwise."""
    g = _int_graph(n, e, seed, relations=relations)
    log = UpdateLog(g)
    store = build_tile_store(g, tile)
    packed = pack_tile_store(store)
    for ep in range(2):
        snap = _random_epoch(log, seed + 11 * ep, n_del=e // 6 + 1,
                             n_ins=e // 4 + 1, grow=grow)
        store, packed, _ = _merged_stores(store, packed, snap)
        _assert_store_eq(store, build_tile_store(snap.graph, tile))
        _assert_store_eq(packed, pack_tile_store(build_tile_store(
            snap.graph, tile)))


def test_delete_to_empty_tiles_compact_away():
    """Deleting every edge of a tile drops the tile from the store (the
    tombstone-compaction contract), still bitwise vs a fresh build."""
    # two far-apart tiles; kill everything in the second one
    src = np.array([0, 1, 60, 61], np.int32)
    dst = np.array([1, 0, 61, 60], np.int32)
    g = COOGraph(64, src, dst, np.ones(4, np.float32))
    store = build_tile_store(g, 8)
    packed = pack_tile_store(store)
    log = UpdateLog(g)
    log.delete(np.array([60, 61]), np.array([61, 60]))
    snap = log.snapshot()
    new_store, new_packed, delta = _merged_stores(store, packed, snap)
    assert delta.tiles_dropped >= 1
    _assert_store_eq(new_store, build_tile_store(snap.graph, 8))
    _assert_store_eq(new_packed, pack_tile_store(build_tile_store(
        snap.graph, 8)))
    # ...and delete-to-fully-empty still round-trips
    log.delete(np.array([0, 1]), np.array([1, 0]))
    snap2 = log.snapshot()
    empty_store, empty_packed, _ = _merged_stores(new_store, new_packed,
                                                  snap2)
    assert empty_store.nnzb == 0 and empty_packed.val.size == 0
    _assert_store_eq(empty_store, build_tile_store(snap2.graph, 8))


def test_untouched_tiles_copy_bitwise_from_old_packed():
    """The packed merge's copy path: tiles outside the delta must carry
    over the *identical* entry bytes (same values, not just equal)."""
    g = _int_graph(96, 400, 3)
    store = build_tile_store(g, 16)
    packed = pack_tile_store(store)
    log = UpdateLog(g)
    log.insert(0, 1, 2.0)                 # touches exactly one tile
    snap = log.snapshot()
    new_store, new_packed, delta = _merged_stores(store, packed, snap)
    assert delta.touched_tiles.size < new_store.nnzb
    _assert_store_eq(new_packed, pack_tile_store(new_store))


# ---------------------------------------------------- executor parity
@settings(max_examples=8, deadline=None)
@given(n=st.integers(8, 110), e=st.integers(2, 450),
       seed=st.integers(0, 4), tile=st.integers(5, 22),
       fmt=st.sampled_from(["dense", "packed"]),
       op=st.sampled_from(["sum", "max", "mean"]))
def test_property_executor_apply_updates_parity(n, e, seed, tile, fmt, op):
    """`TiledExecutor.apply_updates` over two epochs aggregates
    bitwise-identically to a fresh executor on the final graph, in both
    tile formats, and never rebuilds the store."""
    g = _int_graph(n, e, seed)
    ex = TiledExecutor(g, tile=tile, chunk=3, tile_format=fmt)
    log = UpdateLog(g)
    for ep in range(2):
        snap = _random_epoch(log, seed + 7 * ep, n_del=e // 5 + 1,
                             n_ins=e // 3 + 1, grow=(5 if ep else 0))
        ex.apply_updates(snap)
    assert ex.stats.store_builds == 1 and ex.stats.delta_merges == 2
    x = _int_features(log.graph.num_vertices, 6, seed)
    fresh = TiledExecutor(log.graph, tile=tile, chunk=3, tile_format=fmt)
    got = np.asarray(ex.aggregate(x, op))
    want = np.asarray(fresh.aggregate(x, op))
    assert np.array_equal(got, want), (fmt, op, tile)


# ---------------------------------------------------- plan-level parity
@pytest.mark.parametrize("fmt", ["dense", "packed"])
@pytest.mark.parametrize("backend", ["blocked", "tiled", "ring"])
def test_update_plan_matches_fresh_prepare(backend, fmt):
    """`update_plan` across the full backend x format matrix: the
    re-priced plan aggregates bitwise like a from-scratch
    `prepare_graph` of the epoch graph.  The tiled cell must take the
    incremental path (store_builds stays 1); the others re-prepare."""
    g = _int_graph(96, 420, 2)
    cfg = EnGNConfig(in_dim=6, out_dim=6, backend=backend,
                     tile=(4 if backend == "ring" else 16),
                     tile_format=fmt,
                     ring_shards=(RING_SHARDS if backend == "ring"
                                  else None))
    plan = prepare_graph(g, cfg)
    log = UpdateLog(g)
    for ep in range(2):
        snap = _random_epoch(log, 31 + ep, n_del=60, n_ins=90,
                             grow=(7 if ep else 0))
        plan = update_plan(plan, snap, cfg)
    if backend == "tiled":
        st_ = plan.carrier["tiled_exec"].stats
        assert st_.store_builds == 1 and st_.delta_merges == 2
    assert plan.n == log.graph.num_vertices
    x = _int_features(log.graph.num_vertices, 6, 2)
    fresh = prepare_graph(log.graph, cfg)
    if backend == "tiled":      # tiled runs its own executor, not _aggregate
        # the budget gate re-priced for the grown store, not the stale one
        for k in ("q", "host_bytes", "queue_plan",
                  "resident_feature_bytes"):
            assert plan.meta[k] == fresh.meta[k], k
        got = np.asarray(plan.carrier["tiled_exec"].aggregate(x, "sum"))
        want = np.asarray(fresh.carrier["tiled_exec"].aggregate(x, "sum"))
    else:
        layer = EnGNLayer(cfg)
        got = np.asarray(layer._aggregate(plan, jnp.asarray(x)))
        want = np.asarray(layer._aggregate(fresh, jnp.asarray(x)))
    assert np.array_equal(got, want), (backend, fmt)


def test_update_plan_spill_rebuilds_and_carries_counters():
    """When the update-time dim outgrows the fitted step (here: the
    plan was priced for inference, the update arrives under a training
    config whose backward streams double the width), `update_plan`
    re-prepares from scratch — tile re-fitted for the wider dim — and
    the rebuild shows up in store_builds instead of silently resetting
    the counters."""
    g = _int_graph(64, 300, 4)
    infer = EnGNConfig(in_dim=16, out_dim=16, backend="tiled", tile=32,
                       tiled_chunk=2, device_budget_bytes=21_000)
    plan = prepare_graph(g, infer)
    assert plan.meta["tile"] == 32      # the step fits at the full tile
    log = UpdateLog(g)
    snap = _random_epoch(log, 5, n_del=20, n_ins=60)
    train = dataclasses.replace(infer, training=True)
    plan2 = update_plan(plan, snap, train)
    st_ = plan2.carrier["tiled_exec"].stats
    assert st_.store_builds >= 2, "expected a budget-forced rebuild"
    assert plan2.meta["tile"] < 32      # re-fitted for the 2x-wide dim
    assert plan2.n == log.graph.num_vertices
    x = _int_features(log.graph.num_vertices, 16, 4)
    want = np.asarray(prepare_graph(log.graph, train)
                      .carrier["tiled_exec"].aggregate(x, "sum"))
    got = np.asarray(plan2.carrier["tiled_exec"].aggregate(x, "sum"))
    assert np.array_equal(got, want)


def test_update_plan_mean_tracks_new_in_degrees():
    """mean divides by in-counts; the merged store's counts must be the
    epoch graph's, not the stale base's (exact small-int division)."""
    g = _int_graph(40, 160, 6)
    cfg = EnGNConfig(in_dim=5, out_dim=5, aggregate_op="mean",
                     backend="tiled", tile=8)
    plan = prepare_graph(g, cfg)
    log = UpdateLog(g)
    snap = _random_epoch(log, 9, n_del=30, n_ins=50)
    plan = update_plan(plan, snap, cfg)
    x = _int_features(log.graph.num_vertices, 5, 6)
    ev = jnp.asarray(x)[jnp.asarray(log.graph.src)] \
        * jnp.asarray(log.graph.weights())[:, None]
    want = np.asarray(segment_aggregate(ev, jnp.asarray(log.graph.dst),
                                        log.graph.num_vertices, "mean"))
    got = np.asarray(plan.carrier["tiled_exec"].aggregate(x, "mean"))
    assert np.array_equal(got, want)


# ---------------------------------------------------- cache maintenance
def test_cache_invalidate_drops_rows_but_keeps_pins():
    deg = np.arange(10)[::-1].astype(np.float32)   # vertex 0 hottest
    c = DegreeAwareCache(capacity=6, degrees=deg, reserved_frac=0.5)
    c.insert(np.arange(6), np.ones((6, 4), np.float32))
    pinned_before = set(c.pinned_ids)
    dropped = c.invalidate([0, 5, 9])       # 9 was never cached
    assert dropped == 2
    assert c.stats["invalidations"] == 2
    assert set(c.pinned_ids) == pinned_before   # ids stay pinned
    mask, _ = c.lookup(np.array([0, 5]))
    assert not mask.any()                   # rows are gone...
    c.insert(np.array([0]), np.zeros((1, 4), np.float32))
    mask, _ = c.lookup(np.array([0]))
    assert mask.all()                       # ...but refill re-pins


def test_cache_pin_drift_and_repin():
    deg = np.arange(8, dtype=np.float32)    # vertex 7 hottest
    c = DegreeAwareCache(capacity=4, degrees=deg, reserved_frac=0.5)
    assert c.pin_drift(deg) == 0.0
    flipped = deg[::-1].copy()              # now vertex 0 hottest
    assert c.pin_drift(flipped) == 1.0
    c.insert(np.array([7, 0]), np.ones((2, 3), np.float32))
    changed = c.repin(flipped)
    assert changed == 4 and c.stats["repins"] == 1
    assert set(c.pinned_ids) == {0, 1}
    # old pin 7's row was demoted to LRU, new pin 0's was promoted
    mask, _ = c.lookup(np.array([7, 0]))
    assert mask.all()
    assert 0 in c._pinned and 7 in c._lru


# ---------------------------------------------------- serving parity
def test_serving_engine_updates_match_cold_engine():
    """After mid-traffic epochs the long-lived engine — surviving cache
    rows included — answers bitwise like a cold engine on the final
    graph (exact no-fanout extraction, the regime where cached rows are
    reproducible)."""
    from repro.core.models import init_stack, make_gnn_stack
    from repro.serving import GNNServingEngine, ServingConfig

    g = _int_graph(120, 700, 8)
    x0 = _int_features(120, 6, 8)
    layers = make_gnn_stack("gcn", [6, 8, 4])
    params = init_stack(layers, jax.random.key(0))
    cfg = ServingConfig(batch_size=32, num_hops=2, cache_capacity=64,
                        warm_cache=False)
    eng = GNNServingEngine(g, x0, layers, params, cfg)
    rng = np.random.default_rng(8)
    log = UpdateLog(g)
    rid = 0
    for ep in range(2):
        for _ in range(3):                  # warm some cache rows
            ids = rng.integers(0, log.graph.num_vertices, 20)
            eng.submit(rid, ids.astype(np.int32))
            eng.drain()
            rid += 1
        snap = _random_epoch(log, 13 + ep, n_del=25, n_ins=40,
                             grow=(6 if ep else 0))
        x_new = _int_features(snap.graph.num_vertices, 6, 8)
        x_new[:x0.shape[0]] = x0
        info = eng.apply_updates(snap, x_new=x_new)
        assert info["invalidated"] >= 0 and info["affected"] > 0
        x0 = x_new
    assert eng.stats["updates_applied"] == 2
    cold = GNNServingEngine(log.graph, x0, layers, params,
                            ServingConfig(batch_size=32, num_hops=2,
                                          warm_cache=False))
    ids = np.unique(rng.integers(0, log.graph.num_vertices, 48)
                    ).astype(np.int32)
    eng.submit(rid, ids)
    cold.submit(rid, ids)
    got = np.asarray(eng.drain()[0].outputs)
    want = np.asarray(cold.drain()[0].outputs)
    assert np.array_equal(got, want)
