"""Docs integrity gate: relative links + the DESIGN.md anchor contract.

Two checks, both run by the CI `docs` job (and `make check-docs`):

1. **Relative markdown links resolve.**  Every `[text](target)` in the
   repo's markdown files whose target is a relative path must point at
   a file that exists; if the link carries a `#fragment` into another
   markdown file, the fragment must match a heading there (GitHub's
   anchor-slug rules).  External (`http(s)://`, `mailto:`) links are
   skipped — this gate is about the repo staying self-consistent, not
   about the internet being up.

2. **Docstring citations of DESIGN.md resolve.**  Module docstrings
   cite design chapters as "DESIGN.md C7" (also "DESIGN.md C9/C10").
   DESIGN.md's chapter numbers are a stable contract — chapters only
   append — so a citation of a chapter with no matching `## Sn.` /
   `## Cn.` heading is a build error, not a soft warning.  Matching is
   exact on the chapter id (C1 never prefix-matches C10/C11).

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# PAPER/PAPERS/SNIPPETS are retrieval artifacts (may carry links into
# the corpus they were extracted from), not repo-authored docs
SKIP_MD = {"PAPER.md", "PAPERS.md", "SNIPPETS.md"}
MD_FILES = sorted(
    p for p in REPO.glob("**/*.md")
    if p.name not in SKIP_MD
    and not any(part.startswith(".") or part == "__pycache__"
                for part in p.relative_to(REPO).parts)
)
CODE_DIRS = ("src", "tests", "benchmarks", "examples", "tools")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CITE_RE = re.compile(r"DESIGN\.md\s+([SC]\d+(?:/[SC]?\d+)*)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)
CHAPTER_RE = re.compile(r"^##\s+([SC]\d+)\.", re.MULTILINE)


def github_anchor(heading: str) -> str:
    """GitHub's heading → anchor slug: lowercase, drop everything but
    alphanumerics/spaces/hyphens/underscores, spaces become hyphens."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    kept = [c for c in heading.lower() if c.isalnum() or c in " -_"]
    return "".join(kept).replace(" ", "-")


def md_anchors(path: Path) -> set:
    text = path.read_text(encoding="utf-8")
    return {github_anchor(m.group(2)) for m in HEADING_RE.finditer(text)}


def check_links() -> list:
    errors = []
    for md in MD_FILES:
        text = md.read_text(encoding="utf-8")
        # strip fenced code blocks: example links in there aren't claims
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = md if not path_part else (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
                continue
            if frag and dest.suffix == ".md":
                if frag not in md_anchors(dest):
                    errors.append(f"{md.relative_to(REPO)}: dangling "
                                  f"anchor -> {target}")
    return errors


def design_chapters() -> set:
    text = (REPO / "DESIGN.md").read_text(encoding="utf-8")
    return {m.group(1) for m in CHAPTER_RE.finditer(text)}


def check_citations() -> list:
    chapters = design_chapters()
    errors = []
    for d in CODE_DIRS:
        for py in sorted((REPO / d).glob("**/*.py")):
            if "__pycache__" in py.parts:
                continue
            for i, line in enumerate(py.read_text(encoding="utf-8")
                                     .splitlines(), 1):
                for m in CITE_RE.finditer(line):
                    for part in m.group(1).split("/"):
                        cid = part if part[0] in "SC" else m.group(1)[0] + part
                        if cid not in chapters:
                            errors.append(
                                f"{py.relative_to(REPO)}:{i}: cites "
                                f"DESIGN.md {cid} but no '## {cid}.' "
                                f"heading exists")
    return errors


def main() -> int:
    errors = check_links() + check_citations()
    chapters = sorted(design_chapters(),
                      key=lambda c: (c[0], int(c[1:])))
    print(f"checked {len(MD_FILES)} markdown files; DESIGN.md chapters: "
          f"{' '.join(chapters)}")
    if errors:
        print(f"\n{len(errors)} docs error(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("all relative links and DESIGN.md citations resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
